"""§Roofline: render the full (arch x shape x mesh) baseline table from the
dry-run JSONs (results/dryrun/*.json).

    PYTHONPATH=src python -m benchmarks.roofline_table [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List

from benchmarks.common import BenchResult, csv, table

HBM_GIB = 16.0


def load_rows(dirname: str = "results/dryrun") -> List[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def render(rows: List[dict]) -> BenchResult:
    trows, csv_rows = [], []
    for d in rows:
        r = d["roofline"]
        mem = d["memory"]
        live_gib = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
        fits = "yes" if live_gib <= HBM_GIB else f"NO ({live_gib:.0f}G)"
        trows.append([
            f"{d['arch']}/{d['shape']}/{d['mesh']}",
            f"{d['flops_per_device']:.2e}",
            f"{d['bytes_per_device']:.2e}",
            f"{d['collective_bytes']:.2e}",
            r["compute_s"] * 1e3, r["memory_s"] * 1e3,
            r["collective_s"] * 1e3,
            f"**{r['dominant']}**",
            r["useful_ratio"], r["mfu"], fits,
        ])
        csv_rows.append(csv(
            "roofline", cell=f"{d['arch']}/{d['shape']}/{d['mesh']}",
            compute_ms=r["compute_s"] * 1e3,
            memory_ms=r["memory_s"] * 1e3,
            collective_ms=r["collective_s"] * 1e3,
            dominant=r["dominant"], mfu=r["mfu"],
            useful=r["useful_ratio"], live_gib=live_gib))
    md = table(
        ["cell", "FLOPs/dev", "bytes/dev", "coll B/dev", "compute ms",
         "memory ms", "coll ms", "dominant", "useful", "MFU@bound",
         "fits 16G"],
        trows)
    return BenchResult("roofline_table", "§Roofline (from dry-run)", md,
                       csv_rows)


def run(quick: bool = False) -> BenchResult:
    rows = load_rows()
    if not rows:
        return BenchResult(
            "roofline_table", "§Roofline",
            "(no dry-run JSONs found — run "
            "`python -m repro.launch.dryrun --all` first)\n", [])
    return render(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    res = render(load_rows(args.dir))
    print(res.markdown)


if __name__ == "__main__":
    main()
