"""Paper Fig 4/5 (+ §V.B tiles): matrix-unit throughput/latency vs
(parallel tiles x ILP), plus the aligned-vs-misaligned tile sweep."""

from __future__ import annotations

from benchmarks.common import BenchResult, csv, table
from repro.core.probes import matmul


def run(quick: bool = False) -> BenchResult:
    iters = 3 if quick else 8
    batches = (1, 4, 16) if quick else (1, 2, 4, 8, 16, 32)
    ilps = (1, 2, 4) if quick else (1, 2, 4, 6, 8)
    pts = matmul.warp_ilp_sweep(batches=batches, ilps=ilps, iters=iters)
    sat = matmul.saturation_point(pts)
    rows = [[p.batch, p.ilp, p.runtime_ms, p.tflops] for p in pts]
    csv_rows = [csv("fig4_5_matmul", batch=p.batch, ilp=p.ilp,
                    runtime_ms=p.runtime_ms, tflops=p.tflops)
                for p in pts]
    md = table(["tiles (warp analogue)", "ILP", "ms", "TFLOP/s"], rows)
    md += (f"\nSaturation at tiles={sat.batch}, ILP={sat.ilp} "
           f"({sat.tflops:.2f} TFLOP/s) — paper: GB203 saturates at ILP=6/"
           f"25 warps, GH100 at ILP=5/29 warps (more ILP, fewer warps on "
           f"the newer part).\n")
    csv_rows.append(csv("fig4_5_matmul", batch=sat.batch, ilp=sat.ilp,
                        saturation=1))

    tiles = matmul.tile_sweep(iters=iters) if not quick else \
        matmul.tile_sweep(iters=iters, shapes=[(128, 128, 128),
                                               (127, 127, 127),
                                               (512, 512, 512)])
    trows = [[f"{p.m}x{p.n}x{p.k}", "yes" if p.aligned else "NO",
              p.runtime_ms, p.tflops] for p in tiles]
    for p in tiles:
        csv_rows.append(csv("tile_sweep", shape=f"{p.m}x{p.n}x{p.k}",
                            aligned=int(p.aligned), tflops=p.tflops))
    md += "\n**Tile alignment sweep (§V.B analogue)**\n\n" + table(
        ["shape", "MXU-aligned", "ms", "TFLOP/s"], trows)
    aligned_best = max(p.tflops for p in tiles if p.aligned)
    mis = [p for p in tiles if not p.aligned]
    if mis:
        mis_best = max(p.tflops for p in mis)
        md += (f"\nMisaligned tiles reach {mis_best/aligned_best:.0%} of "
               f"aligned throughput (padding waste — the paper's "
               f"operand-staging story).\n")
    return BenchResult("fig4_5_matmul", "Figures 4 and 5, §V.B", md,
                       csv_rows)
