"""Run the microbenchmark probe suite (the paper's methodology) against
THIS backend and print the characterization tables — §IV latency, §V
matmul/precision, §VI memory hierarchy.

    PYTHONPATH=src python examples/characterize.py
"""

from repro import compat
from repro.core import detect_backend_model
from repro.core.probes import compute, matmul, memory, precision
from repro.core.report import dataclass_table


def main() -> None:
    # capability header: records which paths run native vs. emulated
    print(compat.report())
    print()

    dev = detect_backend_model()
    print(f"backend device model: {dev.name} "
          f"(clock {dev.clock_hz/1e9:.2f} GHz)\n")

    print("== §IV execution-pipeline latency (Tab III analogue) ==")
    rows = compute.latency_table(iters=8)
    print(dataclass_table(rows, ["workload", "support", "true_cycles",
                                 "completion_cycles"]))

    print("== §IV.C fp64 emulation factor ==")
    print(f"fp64/fp32 = {compute.fp64_emulation_factor(iters=8):.2f}x\n")

    print("== §V matmul saturation (Fig 4/5 analogue) ==")
    pts = matmul.warp_ilp_sweep(batches=(1, 4, 16), ilps=(1, 2, 4),
                                iters=4)
    sat = matmul.saturation_point(pts)
    print(f"saturates at tiles={sat.batch} ilp={sat.ilp} "
          f"({sat.tflops:.2f} TFLOP/s)\n")

    print("== §V.A precision support matrix (Tab IV/V analogue) ==")
    print(dataclass_table(precision.support_matrix(),
                          ["fmt", "bits", "representable", "pipeline"]))

    print("== §VI.A memory hierarchy walk (Fig 6 analogue) ==")
    curve = memory.chase_curve(
        sizes=tuple(1 << p for p in range(14, 27, 2)), steps=1 << 13,
        iters=4)
    print(dataclass_table(curve))
    bounds = memory.find_boundaries(curve)
    print(f"hierarchy boundaries near: {bounds} bytes\n")

    print("== §VI.D streaming bandwidth (Fig 10 analogue) ==")
    print(dataclass_table(memory.stream_bandwidth(iters=4)))


if __name__ == "__main__":
    main()
