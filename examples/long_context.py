"""Long-context decode: why SSM/hybrid archs run the 500k cell.

Decodes a (reduced) mamba2 and a gemma2 (ring-buffer local layers) far
past any attention window, printing the cache footprint as the position
grows — O(1) for the SSM, O(window) for gemma2's local layers, vs the
O(position) a pure full-attention cache would need.

    PYTHONPATH=src python examples/long_context.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model


def cache_bytes(cache) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(cache))


def run(arch: str, positions=(64, 256, 1024)) -> None:
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = max(positions) + 8
    prompt = jnp.ones((1, 16), jnp.int32)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq))(params,
                                                   {"tokens": prompt})
    print(f"\n{arch}: cache {cache_bytes(cache)/2**20:.2f} MiB "
          f"(max_seq={max_seq})")
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((1,), jnp.int32)
    pos = 16
    for target in positions:
        while pos < target:
            lg, cache = step(params, cache, tok,
                             jnp.full((1,), pos, jnp.int32))
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            pos += 1
        print(f"  pos {pos:5d}: logits finite={bool(jnp.isfinite(lg).all())}"
              f"  cache {cache_bytes(cache)/2**20:.2f} MiB")


def main() -> None:
    run("mamba2-2.7b")        # O(1) state
    run("gemma2-2b")          # ring-buffered local + full global layers
    print("\nA pure full-attention arch at 500k positions would hold "
          "O(position) KV — the reason qwen/llama/gemma skip long_500k "
          "in the dry-run matrix (DESIGN.md §5).")


if __name__ == "__main__":
    main()
