"""Long-context decode: why SSM/hybrid archs run the 500k cell.

Decodes a (reduced) mamba2 and a gemma2 (ring-buffer local layers) far
past any attention window, printing the cache footprint as the position
grows — O(1) for the SSM, O(window) for gemma2's local layers, vs the
O(position) a pure full-attention cache would need.

The gemma2 pass then repeats with a *quantized* KV cache
(``kv_format="float4_e2m1fn"``: nibble-packed codes + 1-byte e8m0
scales) and prints the **measured** KV bytes/token next to the dense
number — at long context the KV read dominates decode HBM traffic
(paper §VI.D), so shrinking the stored bytes (not the nominal width) is
the lever that moves the roofline.

    PYTHONPATH=src python examples/long_context.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, kv_cache_stats


def cache_bytes(cache) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(cache))


def run(arch: str, positions=(64, 256, 1024), kv_format: str = "") -> None:
    cfg = get_config(arch).reduced()
    if kv_format:
        cfg = dataclasses.replace(cfg, kv_format=kv_format)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = max(positions) + 8
    prompt = jnp.ones((1, 16), jnp.int32)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq))(params,
                                                   {"tokens": prompt})
    kv = kv_cache_stats(cache, cfg)
    label = f"{arch} (kv={kv_format})" if kv_format else arch
    print(f"\n{label}: cache {cache_bytes(cache)/2**20:.2f} MiB "
          f"(max_seq={max_seq})")
    if kv["kv_bytes"]:
        print(f"  measured KV store: {kv['kv_bytes']/2**10:.1f} KiB, "
              f"{kv['bytes_per_token']:.0f} B/token across the stack, "
              f"{kv['bytes_per_elem']:.3g} B/elem")
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((1,), jnp.int32)
    pos = 16
    for target in positions:
        while pos < target:
            lg, cache = step(params, cache, tok,
                             jnp.full((1,), pos, jnp.int32))
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            pos += 1
        print(f"  pos {pos:5d}: logits finite={bool(jnp.isfinite(lg).all())}"
              f"  cache {cache_bytes(cache)/2**20:.2f} MiB")


def main() -> None:
    run("mamba2-2.7b")        # O(1) state
    run("gemma2-2b")          # ring-buffered local + full global layers
    # same ring caches, truly-packed fp4 KV + 1-byte e8m0 scales: the
    # measured B/token drops ~7x vs the fp32 smoke dtype (~3.6x vs bf16)
    run("gemma2-2b", kv_format="float4_e2m1fn")
    print("\nA pure full-attention arch at 500k positions would hold "
          "O(position) KV — the reason qwen/llama/gemma skip long_500k "
          "in the dry-run matrix (DESIGN.md §5).  The quantized cache "
          "composes with the ring buffer: O(window) slots x ~0.56 B/elem "
          "stored (measured), and repro.kernels.flash_decode_quant "
          "streams those packed bytes straight through VMEM.")


if __name__ == "__main__":
    main()
