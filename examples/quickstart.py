"""Quickstart: train a tiny qwen-family model on the synthetic affine task,
checkpoint it, and serve a few generations — the whole stack in ~1 minute
on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import tempfile

import jax

from repro.configs import get_config, smoke_shape
from repro.data import make_stream
from repro.models import build_model
from repro.optim import AdamWConfig, Schedule
from repro.serve import ServeEngine
from repro.train import (TrainLoopConfig, make_train_step, run_train_loop,
                         train_state_init)


def main() -> None:
    cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(),
                              n_layers=2)
    model = build_model(cfg)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.2f}M params)")

    opt = AdamWConfig(schedule=Schedule(peak_lr=1e-2, warmup_steps=5,
                                        decay_steps=100))
    state = train_state_init(model, opt, jax.random.PRNGKey(0))
    stream = make_stream(cfg, smoke_shape("train"))
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    with tempfile.TemporaryDirectory() as ckdir:
        state, history = run_train_loop(
            step, state, stream,
            TrainLoopConfig(total_steps=60, checkpoint_every=30,
                            checkpoint_dir=ckdir, log_every=10))

    print("\nserving the trained model (greedy):")
    engine = ServeEngine(model, state["params"], batch=2, max_seq=96)
    # the affine task: t_{i+1} = (5 t_i + 17) mod 97 — the model should
    # continue the chain
    prompt = [3]
    x = 3
    for _ in range(15):
        x = (5 * x + 17) % 97
        prompt.append(x)
    engine.submit(prompt, max_new_tokens=8)
    result = engine.run()[0]
    want = []
    for _ in range(8):
        x = (5 * x + 17) % 97
        want.append(x)
    print(f"  prompt tail : {prompt[-4:]}")
    print(f"  generated   : {result.tokens}")
    print(f"  ground truth: {want}")
    hits = sum(int(a == b) for a, b in zip(result.tokens, want))
    print(f"  -> {hits}/8 continuations correct")


if __name__ == "__main__":
    main()
