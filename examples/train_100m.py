"""End-to-end training driver: a ~100M-parameter llama-family model for a
few hundred steps on the synthetic zipf+affine mixture, with
checkpoint/restart, straggler watchdog, and metrics logging — the
deliverable-(b) production-shaped run, sized for a CPU container.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    (interrupt it and re-run with the same --ckpt to watch it resume)
"""

import argparse
import dataclasses

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.models import build_model
from repro.optim import AdamWConfig, Schedule
from repro.train import (TrainLoopConfig, make_train_step, run_train_loop,
                         train_state_init)

# ~112M params: a small llama3-family config
CONFIG_100M = ArchConfig(
    name="llama-100m",
    family="dense",
    n_layers=14,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=16384,
    mlp_variant="swiglu",
    tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = CONFIG_100M
    model = build_model(cfg)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    shape = ShapeConfig("train100m", "train", args.seq, args.batch)
    stream = SyntheticStream(cfg, shape, SyntheticConfig(kind="affine"))
    opt = AdamWConfig(
        schedule=Schedule(peak_lr=args.lr, warmup_steps=30,
                          decay_steps=args.steps))
    state = train_state_init(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    state, history = run_train_loop(
        step, state, stream,
        TrainLoopConfig(total_steps=args.steps,
                        checkpoint_every=max(args.steps // 5, 20),
                        checkpoint_dir=args.ckpt, log_every=10))
    print(f"done: loss {history[0]['loss']:.3f} -> "
          f"{history[-1]['loss']:.3f}, acc {history[-1]['acc']:.3f}")


if __name__ == "__main__":
    main()
