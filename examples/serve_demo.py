"""Batched serving with continuous batching + the Tab VIII precision sweep:
the same GPT-NeoX-family model served at bf16 / fp8 / fp6 / fp4 weight
storage, reporting throughput, quantization error, model bytes, and the
v5e energy-model watts per precision.

    PYTHONPATH=src python examples/serve_demo.py
"""

import time

import jax

from repro.configs import get_config
from repro.core import TPU_V5E
from repro.core.energy import estimate
from repro.models import build_model
from repro.serve import ServeEngine, quantize_params


def main() -> None:
    cfg = get_config("gptneox-1b").reduced()
    model = build_model(cfg)
    base = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} (reduced: {cfg.param_count()/1e6:.2f}M) "
          f"across precisions\n")
    print(f"{'precision':16s} {'tok/s':>8s} {'bytes MiB':>10s} "
          f"{'rel-MSE':>9s} {'v5e W (model)':>13s}")

    for fmt in ("float32", "bfloat16", "float8_e4m3fn",
                "float6_e2m3fn", "float4_e2m1fn"):
        params, q = quantize_params(base, fmt)
        eng = ServeEngine(model, params, batch=4, max_seq=96,
                          temperature=0.0)
        for i in range(8):
            eng.submit(list(range(1 + i, 17 + i)), max_new_tokens=8)
        t0 = time.perf_counter()
        results = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in results)
        full = get_config("gptneox-1b")
        frac = q["quantized_bytes"] / max(
            sum(x.nbytes for x in jax.tree.leaves(base)), 1)
        hbm = full.active_param_count() * 2 * frac
        watts = estimate(TPU_V5E, flops=2.0 * full.active_param_count(),
                         dtype=fmt, bytes_by_level={"hbm": hbm},
                         seconds=hbm / TPU_V5E.hbm.bandwidth_Bps
                         ).total_watts
        print(f"{fmt:16s} {toks/dt:8.1f} "
              f"{q['quantized_bytes']/2**20:10.1f} {q['mse']:9.2e} "
              f"{watts:13.1f}")

    print("\n(the paper's Tab VIII: H100 57.7-60.2 W flat vs RTX 5080 "
          "58.8 -> 45.1 W from FP32 to FP8 — decode is weight-read bound, "
          "so storage precision is the power lever)")


if __name__ == "__main__":
    main()
