"""Batched serving with the device-resident fused decode loop + the
Tab VIII precision sweep: the same GPT-NeoX-family model served at
bf16 / fp8 / fp6 / fp4 weight storage, reporting fused vs per-token
throughput, quantization error, model bytes, and the v5e energy-model
watts per precision.

Slot state (pos / remaining / last_token / active / rng seed) lives in
device arrays and one dispatch advances ``decode_block`` tokens, so the
tok/s column measures the decode step body — not a host↔device round
trip per token (the per-step column shows what that used to cost).

    PYTHONPATH=src python examples/serve_demo.py
"""

import time

import jax

from repro.configs import get_config
from repro.core import TPU_V5E
from repro.core.energy import estimate
from repro.models import build_model
from repro.serve import ServeEngine, quantize_params


def _serve(eng: ServeEngine) -> float:
    """Enqueue 8 requests, serve, return tok/s."""
    eng.reset()
    for i in range(8):
        eng.submit(list(range(1 + i, 17 + i)), max_new_tokens=8)
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    return sum(len(r.tokens) for r in results) / dt


def main() -> None:
    cfg = get_config("gptneox-1b").reduced()
    model = build_model(cfg)
    base = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} (reduced: {cfg.param_count()/1e6:.2f}M) "
          f"across precisions — fused K=16 loop vs per-token dispatch\n")
    print(f"{'precision':16s} {'tok/s fused':>11s} {'tok/s step':>10s} "
          f"{'bytes MiB':>10s} {'rel-MSE':>9s} {'v5e W (model)':>13s}")

    for fmt in ("float32", "bfloat16", "float8_e4m3fn",
                "float6_e2m3fn", "float4_e2m1fn"):
        params, q = quantize_params(base, fmt)
        fused = ServeEngine(model, params, batch=4, max_seq=96,
                            temperature=0.0, decode_block=16)
        per_step = ServeEngine(model, params, batch=4, max_seq=96,
                               temperature=0.0, decode_block=1)
        _serve(fused)                       # warm-up absorbs compilation
        _serve(per_step)
        tps_fused, tps_step = _serve(fused), _serve(per_step)
        full = get_config("gptneox-1b")
        frac = q["quantized_bytes"] / max(
            sum(x.nbytes for x in jax.tree.leaves(base)), 1)
        hbm = full.active_param_count() * 2 * frac
        watts = estimate(TPU_V5E, flops=2.0 * full.active_param_count(),
                         dtype=fmt, bytes_by_level={"hbm": hbm},
                         seconds=hbm / TPU_V5E.hbm.bandwidth_Bps
                         ).total_watts
        print(f"{fmt:16s} {tps_fused:11.1f} {tps_step:10.1f} "
              f"{q['quantized_bytes']/2**20:10.1f} {q['mse']:9.2e} "
              f"{watts:13.1f}")

    print("\n(the paper's Tab VIII: H100 57.7-60.2 W flat vs RTX 5080 "
          "58.8 -> 45.1 W from FP32 to FP8 — decode is weight-read bound, "
          "so storage precision is the power lever; and §IV.A: the "
          "fused-vs-step gap is pure dispatch overhead, which a per-token "
          "loop would otherwise report as model speed)")


if __name__ == "__main__":
    main()
